//! Table II — computing/communication overlap matrix.
//!
//! | task                        | PyTorch | MTE | WRR |
//! |-----------------------------|---------|-----|-----|
//! | CSD Preprocess              |    ×    |  ✓  |  ✓  |
//! | Transfer CSD Data (GDS)     |    ×    |  ×  |  ✓  |
//! | CPU Preprocess              |    ✓    |  ✓  |  ✓  |
//! | Transfer CPU Data           |    ✓    |  ✓  |  ✓  |
//! | Accelerator Train CPU Data  |    ✓    |  ✓  |  ✓  |
//! | Accelerator Train CSD Data  |    ×    |  ×  |  ✓  |
//!
//! Rows are "does this activity overlap with *CSD preprocessing*"
//! (the new resource DDLP introduces). We assert the matrix from
//! recorded traces: ✓ → the overlap is substantial, × → (near) zero.

use ddlp::config::{DeviceProfile, ExperimentConfig};
use ddlp::coordinator::cost::FixedCosts;
use ddlp::coordinator::Strategy;
use ddlp::dataset::DatasetSpec;
use ddlp::pipeline::PipelineKind;
use ddlp::trace::{Phase, Span, Trace};

mod common;
use common::run_session;

fn run(strategy: Strategy) -> Trace {
    let mut profile = DeviceProfile::default();
    profile.csd_signal_latency_s = 0.0;
    profile.poll_cost_s = 0.0;
    let cfg = ExperimentConfig::builder()
        .model("wrn")
        .strategy(strategy)
        .num_workers(0)
        .n_batches(600)
        .profile(profile)
        .build()
        .unwrap();
    let spec = DatasetSpec {
        n_batches: 600,
        batch_size: 1,
        pipeline: PipelineKind::ImageNet1,
        seed: 0,
    };
    let mut costs = FixedCosts::toy_fig6();
    run_session(&cfg, &spec, &mut costs).unwrap().1
}

fn csd_pp(s: &Span) -> bool {
    s.phase == Phase::CsdPreprocess
}

/// Batches whose data came from the CSD (they have a GdsRead span).
fn csd_batch_ids(t: &Trace) -> std::collections::HashSet<u32> {
    t.spans
        .iter()
        .filter(|s| s.phase == Phase::GdsRead)
        .map(|s| s.batch.unwrap())
        .collect()
}

#[test]
fn pytorch_row_no_csd_activity() {
    let t = run(Strategy::CpuOnly);
    assert_eq!(t.busy_where(csd_pp), 0.0);
    assert_eq!(t.busy_where(|s| s.phase == Phase::GdsRead), 0.0);
    // CPU preprocess does overlap... nothing else runs concurrently in
    // the coupled single-process baseline, but the activity exists:
    assert!(t.busy_where(|s| s.phase == Phase::CpuPreprocess) > 0.0);
    assert!(t.busy_where(|s| s.phase == Phase::Train) >= 0.0);
}

#[test]
fn mte_overlaps_csd_pp_with_cpu_side_but_not_csd_consumption() {
    let t = run(Strategy::Mte);
    let csd_busy = t.busy_where(csd_pp);
    assert!(csd_busy > 0.0);

    // ✓ CSD preprocess × CPU preprocess: substantial overlap.
    let ov_cpu = t.overlap_where(csd_pp, |s| s.phase == Phase::CpuPreprocess);
    assert!(
        ov_cpu > 0.5 * csd_busy,
        "MTE csd×cpu overlap {ov_cpu:.1} of {csd_busy:.1}"
    );

    // × CSD preprocess × transfer/training of CSD data: near zero —
    // the accelerator turns to CSD data only after the CPU allocation,
    // by which point the CSD has (nearly) finished its own.
    let ids = csd_batch_ids(&t);
    let ov_gds = t.overlap_where(csd_pp, |s| s.phase == Phase::GdsRead);
    let ov_train_csd = t.overlap_where(csd_pp, |s| {
        s.phase == Phase::Train && s.batch.map_or(false, |b| ids.contains(&b))
    });
    assert!(
        ov_gds + ov_train_csd < 0.05 * csd_busy,
        "MTE should not overlap csd-pp with csd-data consumption: {:.2}",
        ov_gds + ov_train_csd
    );
}

#[test]
fn wrr_additionally_overlaps_csd_consumption() {
    let t = run(Strategy::Wrr);
    let csd_busy = t.busy_where(csd_pp);
    let ids = csd_batch_ids(&t);

    // Everything MTE overlaps…
    let ov_cpu = t.overlap_where(csd_pp, |s| s.phase == Phase::CpuPreprocess);
    assert!(ov_cpu > 0.5 * csd_busy);

    // …plus the two activities MTE cannot: GDS transfer of CSD data and
    // training on CSD data, while the CSD keeps preprocessing.
    let ov_train_csd = t.overlap_where(csd_pp, |s| {
        s.phase == Phase::Train && s.batch.map_or(false, |b| ids.contains(&b))
    });
    assert!(
        ov_train_csd > 0.0,
        "WRR must overlap csd-pp with training on csd data"
    );
}

#[test]
fn wrr_overlap_strictly_exceeds_mte() {
    // The mechanism behind WRR's edge (§VI-C factor 3).
    let tm = run(Strategy::Mte);
    let tw = run(Strategy::Wrr);
    let csd_consumption_overlap = |t: &Trace| {
        let ids = csd_batch_ids(t);
        t.overlap_where(
            |s| s.phase == Phase::CsdPreprocess,
            |s| {
                (s.phase == Phase::GdsRead || s.phase == Phase::Train)
                    && s.batch.map_or(false, |b| ids.contains(&b))
            },
        )
    };
    assert!(csd_consumption_overlap(&tw) > csd_consumption_overlap(&tm));
}
