//! Tenancy invariants (DESIGN.md §Tenancy): every job in a plan runs
//! exactly once under every admission policy; concurrently running jobs
//! never share a device (no over-subscription, property-tested over
//! random plans); a single-job plan requesting the whole fleet is
//! **bit-identical** to [`Cluster::run`] on the same config; and the
//! ISSUE acceptance scenario — three mixed-priority jobs with staggered
//! arrivals, one queued behind capacity — conserves per-job batches and
//! populates the fleet rollup. All of it holds at any `PALLAS_THREADS`
//! (CI runs this suite at 1 and 4 and diffs the CLI stdout bit-exact).

use ddlp::cluster::Cluster;
use ddlp::config::ExperimentConfig;
use ddlp::coordinator::cost::{CostProvider, FixedCosts};
use ddlp::coordinator::Strategy;
use ddlp::stage::WorkloadKind;
use ddlp::tenant::{self, JobPlan, JobSpec, Prio, Sched, Tenancy, TenancyResult};
use ddlp::trace::Phase;
use ddlp::util::prop::run_prop;

/// Base config: the fleet plus the plan. Per-job workloads come from
/// `batches=` overrides in the plan itself.
fn cfg(fleet_accel: u32, fleet_csd: u32, jobs: &str, sched: Sched) -> ExperimentConfig {
    ExperimentConfig::builder()
        .model("wrn")
        .strategy(Strategy::Wrr)
        .n_accel(fleet_accel)
        .n_csd(fleet_csd)
        .n_batches(120)
        .jobs(jobs.parse::<JobPlan>().unwrap())
        .sched(sched)
        .build()
        .unwrap()
}

/// Uniform toy costs for every (job, host).
fn run_toy(cfg: &ExperimentConfig) -> TenancyResult {
    Tenancy::new(cfg)
        .unwrap()
        .with_cost_factory(|_job, _host| -> Box<dyn CostProvider + Send> {
            Box::new(FixedCosts::toy_fig6())
        })
        .run()
        .unwrap()
}

/// Every job-local batch id trained exactly `epochs` times in the
/// job's own trace.
fn assert_job_coverage(r: &TenancyResult, job: usize, n: u32, epochs: u32, label: &str) {
    let t = &r.tenants[job];
    assert_eq!(
        t.result.report.n_batches,
        n * epochs,
        "{label}: job {job} batch count"
    );
    let mut counts = vec![0u32; n as usize];
    for s in &t.result.trace.spans {
        if s.phase == Phase::Train {
            counts[s.batch.unwrap() as usize] += 1;
        }
    }
    for (b, &c) in counts.iter().enumerate() {
        assert_eq!(
            c, epochs,
            "{label}: job {job} batch {b} trained {c}×, want {epochs}"
        );
    }
}

/// The fleet trace carries exactly one JobAdmit/JobStart/JobFinish
/// marker per job, chronologically consistent with the report.
fn assert_markers(r: &TenancyResult, label: &str) {
    for (kind, phase) in [
        ("admit", Phase::JobAdmit),
        ("start", Phase::JobStart),
        ("finish", Phase::JobFinish),
    ] {
        let mut seen = vec![0u32; r.tenants.len()];
        for s in r.trace.spans.iter().filter(|s| s.phase == phase) {
            assert_eq!(s.start, s.end, "{label}: {kind} marker has width");
            seen[s.batch.unwrap() as usize] += 1;
        }
        for (j, &c) in seen.iter().enumerate() {
            assert_eq!(c, 1, "{label}: job {j} has {c} {kind} markers, want 1");
        }
    }
    for (j, t) in r.tenants.iter().enumerate() {
        let at = |phase: Phase| {
            r.trace
                .spans
                .iter()
                .find(|s| s.phase == phase && s.batch == Some(j as u32))
                .unwrap()
                .start
        };
        assert_eq!(at(Phase::JobAdmit), t.arrival, "{label}: job {j} admit@arrival");
        assert_eq!(at(Phase::JobStart), t.start, "{label}: job {j} start marker");
        assert_eq!(at(Phase::JobFinish), t.finish, "{label}: job {j} finish marker");
    }
}

/// Jobs whose [start, finish) intervals overlap must hold disjoint
/// device sets, and no job may exceed the fleet.
fn assert_no_oversubscription(r: &TenancyResult, fleet_accel: u32, fleet_csd: u32, label: &str) {
    for (j, t) in r.tenants.iter().enumerate() {
        assert!(
            t.accel_ids.iter().all(|&a| a < fleet_accel),
            "{label}: job {j} accel id out of fleet"
        );
        assert!(
            t.csd_ids.iter().all(|&c| c < fleet_csd),
            "{label}: job {j} csd id out of fleet"
        );
        let mut a = t.accel_ids.clone();
        a.sort_unstable();
        a.dedup();
        assert_eq!(a.len(), t.accel_ids.len(), "{label}: job {j} dup accel id");
        let mut c = t.csd_ids.clone();
        c.sort_unstable();
        c.dedup();
        assert_eq!(c.len(), t.csd_ids.len(), "{label}: job {j} dup csd id");
    }
    for i in 0..r.tenants.len() {
        for j in (i + 1)..r.tenants.len() {
            let (a, b) = (&r.tenants[i], &r.tenants[j]);
            let overlap = a.start < b.finish && b.start < a.finish;
            if overlap {
                assert!(
                    a.accel_ids.iter().all(|x| !b.accel_ids.contains(x)),
                    "{label}: jobs {i}/{j} overlap in time and share an accel"
                );
                assert!(
                    a.csd_ids.iter().all(|x| !b.csd_ids.contains(x)),
                    "{label}: jobs {i}/{j} overlap in time and share a CSD"
                );
            }
        }
    }
}

#[test]
fn exactly_once_per_job_under_every_policy() {
    // Four jobs contending for a 4-accel fleet: a full-fleet job plus
    // three half-fleet jobs. Under every policy, every job runs its
    // whole workload exactly once and the markers agree with the
    // per-job timeline.
    let plan = "big:@0 accel=4 csd=2 batches=80; a:@2 accel=2 csd=1 batches=30; \
                b:@4 accel=2 csd=1 batches=30 prio=hi; c:@4.5 accel=2 csd=1 batches=20 prio=lo";
    for sched in Sched::ALL {
        let label = format!("sched={sched}");
        let r = run_toy(&cfg(4, 2, plan, sched));
        assert_eq!(r.tenants.len(), 4, "{label}");
        assert_eq!(r.fleet.n_jobs, 4, "{label}");
        for (job, n) in [(0usize, 80u32), (1, 30), (2, 30), (3, 20)] {
            assert_job_coverage(&r, job, n, 1, &label);
        }
        assert_markers(&r, &label);
        assert_no_oversubscription(&r, 4, 2, &label);
        assert_eq!(r.fleet.total_batches, 160, "{label}");
        // Timeline sanity: nobody starts before arriving, stretch >= 1.
        for t in &r.tenants {
            assert!(t.start >= t.arrival, "{label}: {} time-traveled", t.name);
            assert!(t.queue_wait >= 0.0, "{label}");
            assert!(t.stretch >= 1.0, "{label}");
            assert_eq!(t.finish, t.start + t.makespan, "{label}");
        }
    }
}

#[test]
fn no_oversubscription_property() {
    // Random plans over random fleets: whatever the policy admits,
    // overlapping jobs never share a device and every job eventually
    // runs exactly its workload.
    run_prop("tenancy_no_oversubscription", 25, |g| {
        let fleet_accel = g.size(2, 8) as u32;
        let fleet_csd = g.size(1, 4) as u32;
        let n_jobs = g.size(2, 5);
        let sched = *g.choose(&Sched::ALL);
        let mut plan = String::new();
        for j in 0..n_jobs {
            let accel = g.size(1, fleet_accel as usize);
            let csd = g.size(1, fleet_csd as usize);
            let arrival = g.int(0, 4) as f64 * 2.5;
            let batches = g.size(10, 40);
            let prio = *g.choose(&["lo", "normal", "hi"]);
            if j > 0 {
                plan.push_str("; ");
            }
            plan.push_str(&format!(
                "j{j}:@{arrival} accel={accel} csd={csd} batches={batches} prio={prio}"
            ));
        }
        let label = format!("sched={sched} plan={plan}");
        let c = cfg(fleet_accel, fleet_csd, &plan, sched);
        let r = run_toy(&c);
        assert_no_oversubscription(&r, fleet_accel, fleet_csd, &label);
        for j in 0..n_jobs {
            let n = c.jobs.jobs[j].n_batches.unwrap();
            assert_job_coverage(&r, j, n, 1, &label);
        }
        assert_markers(&r, &label);
    });
}

#[test]
fn single_job_bit_identical_to_cluster_run() {
    // The tentpole acceptance golden: a one-job plan requesting the
    // whole fleet produces the job config == base config minus `jobs`,
    // so its run must be bit-identical to Cluster::run — report, trace
    // spans, cache counters, per-CSD attribution.
    let solo = ExperimentConfig::builder()
        .model("wrn")
        .strategy(Strategy::Wrr)
        .n_accel(2)
        .n_csd(1)
        .n_batches(120)
        .build()
        .unwrap();
    let tenanted = ExperimentConfig::builder()
        .model("wrn")
        .strategy(Strategy::Wrr)
        .n_accel(2)
        .n_csd(1)
        .n_batches(120)
        .jobs("solo:@0 accel=2 csd=1".parse().unwrap())
        .build()
        .unwrap();

    // Toy costs on both sides.
    let direct = Cluster::from_config(&solo)
        .unwrap()
        .with_cost_factory(|_| -> Box<dyn CostProvider + Send> { Box::new(FixedCosts::toy_fig6()) })
        .run()
        .unwrap();
    let via_tenancy = run_toy(&tenanted);
    let t = &via_tenancy.tenants[0];
    assert_eq!(t.result.report, direct.report, "report diverged");
    assert_eq!(t.result.trace.spans, direct.trace.spans, "trace diverged");
    assert_eq!(t.result.cache, direct.cache, "cache stats diverged");
    assert_eq!(t.result.csd_devices, direct.csd_devices, "csd attribution diverged");
    assert_eq!(t.queue_wait, 0.0);
    assert_eq!(t.stretch, 1.0);
    assert_eq!(t.accel_ids, vec![0, 1]);
    assert_eq!(t.csd_ids, vec![0]);
    assert_eq!(via_tenancy.fleet.fleet_makespan, direct.report.makespan);
    assert_eq!(via_tenancy.fleet.utilization, 1.0);

    // And on the config-derived (analytic) cost path the CLI uses.
    let direct = Cluster::from_config(&solo).unwrap().run().unwrap();
    let via_tenancy = tenant::run(&tenanted).unwrap();
    let t = &via_tenancy.tenants[0];
    assert_eq!(t.result.report, direct.report, "analytic report diverged");
    assert_eq!(t.result.trace.spans, direct.trace.spans, "analytic trace diverged");
}

#[test]
fn acceptance_three_job_mixed_priority_scenario() {
    // ISSUE acceptance: three jobs, mixed priorities, staggered
    // arrivals, one (two, here) queued behind capacity. `big` owns the
    // whole fleet from t=0; its makespan is bounded below by
    // 60 batches/accel × 0.125 s train = 7.5 s, so both later arrivals
    // genuinely queue.
    let plan = "big:@0 accel=4 csd=2 batches=240 prio=hi; \
                med:@3 accel=2 csd=1 batches=60; \
                tiny:@6 accel=2 csd=1 batches=30 prio=lo";
    let r = run_toy(&cfg(4, 2, plan, Sched::Fifo));

    let (big, med, tiny) = (&r.tenants[0], &r.tenants[1], &r.tenants[2]);
    assert_eq!(big.prio, Prio::Hi);
    assert_eq!(tiny.prio, Prio::Lo);
    // big was admitted on arrival and holds the fleet past both arrivals.
    assert_eq!(big.queue_wait, 0.0);
    assert_eq!(big.stretch, 1.0);
    assert!(big.makespan >= 7.5, "toy big job too short: {}", big.makespan);
    // med and tiny queued behind capacity, then started together at
    // big's release (they fit side by side: 2+2 accels, 1+1 CSDs).
    for t in [med, tiny] {
        assert!(t.queue_wait > 0.0, "{} never queued", t.name);
        assert!(t.stretch > 1.0, "{}", t.name);
        assert_eq!(t.start, big.finish, "{} start", t.name);
    }
    assert!(med.queue_wait > tiny.queue_wait, "earlier arrival waited longer");
    assert_no_oversubscription(&r, 4, 2, "acceptance");
    // Per-job conservation.
    assert_job_coverage(&r, 0, 240, 1, "acceptance");
    assert_job_coverage(&r, 1, 60, 1, "acceptance");
    assert_job_coverage(&r, 2, 30, 1, "acceptance");
    assert_markers(&r, "acceptance");

    // Fleet rollup populated and consistent.
    let f = &r.fleet;
    assert_eq!(f.n_jobs, 3);
    assert_eq!(f.total_batches, 330);
    let last = r.tenants.iter().map(|t| t.finish).fold(0.0, f64::max);
    assert_eq!(f.fleet_makespan, last);
    assert!(f.utilization > 0.0 && f.utilization <= 1.0, "{}", f.utilization);
    // waits sorted: [0, tiny, med] → p50 = tiny's, p95 = med's.
    assert_eq!(f.queue_wait_p50, tiny.queue_wait);
    assert_eq!(f.queue_wait_p95, med.queue_wait);
    assert!(f.max_stretch >= f.mean_stretch && f.mean_stretch > 1.0);
    assert!(f.fairness > 0.0 && f.fairness < 1.0, "{}", f.fairness);
    assert!(f.total_joules > 0.0);
}

#[test]
fn fair_share_beats_fifo_max_stretch_on_skewed_mix() {
    // The bench mix in miniature: one long job ahead of three short
    // ones, every job requesting the full fleet so execution
    // serializes. FIFO runs the long job first and stretches every
    // short job by its whole makespan; fair-share (min accel-hours
    // first) runs the shorts first and only stretches the long job a
    // little — strictly lower max stretch.
    let plan = "big:@0 accel=2 csd=1 batches=240; s0:@0 accel=2 csd=1 batches=30; \
                s1:@0 accel=2 csd=1 batches=30; s2:@0 accel=2 csd=1 batches=30";
    let fifo = run_toy(&cfg(2, 1, plan, Sched::Fifo));
    let fair = run_toy(&cfg(2, 1, plan, Sched::Fair));
    // FIFO admits the queue head (plan order on the t=0 tie): big first.
    assert_eq!(fifo.tenants[0].queue_wait, 0.0);
    // Fair admits a short first and big last.
    assert!(fair.tenants[0].queue_wait > 0.0, "fair ran big first");
    assert!(
        fair.fleet.max_stretch < fifo.fleet.max_stretch,
        "fair {} !< fifo {}",
        fair.fleet.max_stretch,
        fifo.fleet.max_stretch
    );
    assert!(
        fair.fleet.mean_stretch < fifo.fleet.mean_stretch,
        "fair {} !< fifo {}",
        fair.fleet.mean_stretch,
        fifo.fleet.mean_stretch
    );
    // Work conserved identically either way.
    assert_eq!(fifo.fleet.total_batches, fair.fleet.total_batches);
}

#[test]
fn priority_admits_hi_first_and_backfills_around_blocked_head() {
    // While j0 holds half the fleet, a hi-prio full-fleet job is
    // blocked; priority lets the later lo-prio half-fleet job backfill
    // around it, FIFO blocks everyone behind the head.
    let plan = "j0:@0 accel=2 csd=1 batches=120; \
                wide:@1 accel=4 csd=2 batches=40 prio=hi; \
                lo:@2 accel=2 csd=1 batches=40 prio=lo";
    let prio = run_toy(&cfg(4, 2, plan, Sched::Priority));
    // Backfill: `lo` fits beside j0 and starts the instant it arrives.
    assert_eq!(prio.tenants[2].queue_wait, 0.0, "priority failed to backfill");
    // `wide` needs the whole fleet: it waits for both.
    assert!(prio.tenants[1].queue_wait > 0.0);
    let fifo = run_toy(&cfg(4, 2, plan, Sched::Fifo));
    // FIFO's blocked head blocks the backfiller too.
    assert!(fifo.tenants[2].queue_wait > 0.0, "fifo should not backfill");

    // And when two jobs are both eligible, hi outranks an earlier lo.
    let plan = "j0:@0 accel=2 csd=1 batches=120; \
                lo:@1 accel=2 csd=1 batches=40 prio=lo; \
                hi:@2 accel=2 csd=1 batches=40 prio=hi";
    let r = run_toy(&cfg(2, 1, plan, Sched::Priority));
    assert!(
        r.tenants[2].start < r.tenants[1].start,
        "hi@2 should start before lo@1: {} vs {}",
        r.tenants[2].start,
        r.tenants[1].start
    );
}

#[test]
fn prop_job_plan_display_parse_round_trip() {
    // The jobs DSL round-trips value → Display → parse → value and the
    // printed form is a fixed point (mirrors the fault-DSL round-trip
    // property): defaulted keys are omitted, arrivals print the
    // shortest f64 text that re-parses to the same bits.
    run_prop("job plan display/parse round-trip", 40, |g| {
        let n_jobs = g.size(1, 6);
        let mut jobs = Vec::new();
        for j in 0..n_jobs {
            jobs.push(JobSpec {
                name: format!("j{j}"),
                arrival: g.float(0.0, 50.0),
                n_accel: g.int(1, 8) as u32,
                n_csd: g.int(0, 4) as u32,
                n_hosts: g.int(1, 3) as u32,
                prio: *g.choose(&[Prio::Lo, Prio::Normal, Prio::Hi]),
                n_batches: if g.bool() { Some(g.int(1, 400) as u32) } else { None },
                epochs: if g.bool() { Some(g.int(1, 4) as u32) } else { None },
            });
        }
        let plan = JobPlan { jobs };
        let text = plan.to_string();
        let reparsed: JobPlan = text
            .parse()
            .unwrap_or_else(|e| panic!("printed plan failed to parse: {text:?}: {e}"));
        assert_eq!(reparsed, plan, "round-trip diverged through {text:?}");
        assert_eq!(reparsed.to_string(), text, "display not a fixed point");
    });
}

#[test]
fn tabular_jobs_carry_stage_attribution_through_tenancy() {
    // Stage-DAG acceptance leg: `workload = tabular` runs end-to-end
    // through Tenancy on the analytic cost path, and every tenant's
    // RunReport carries per-stage attribution with (batch, stage)
    // completions conserved (trained + wasted, identical per stage).
    let cfg = ExperimentConfig::builder()
        .model("wrn")
        .strategy(Strategy::Wrr)
        .n_accel(4)
        .n_csd(2)
        .n_batches(60)
        .workload(WorkloadKind::Tabular)
        .jobs(
            "left:@0 accel=2 csd=1 batches=40; right:@1 accel=2 csd=1 batches=30"
                .parse::<JobPlan>()
                .unwrap(),
        )
        .build()
        .unwrap();
    let r = tenant::run(&cfg).unwrap();
    assert_eq!(r.tenants.len(), 2);
    for t in &r.tenants {
        let report = &t.result.report;
        let st = &report.stages;
        assert!(!st.is_empty(), "{}: no stage attribution", t.name);
        let names: Vec<_> = st.per_stage.iter().map(|s| s.name).collect();
        assert_eq!(names, ["parse", "encode", "normalize", "join"], "{}", t.name);
        let want = report.n_batches as u64 + report.wasted_batches;
        for s in &st.per_stage {
            assert_eq!(
                s.completions, want,
                "{}: stage {} completed {}×, want {want}",
                t.name, s.name, s.completions
            );
        }
        assert_eq!(st.split_hist.iter().sum::<u64>(), want, "{}", t.name);
        assert!(
            st.per_stage
                .iter()
                .all(|s| s.host_busy_s + s.csd_busy_s > 0.0),
            "{}: a stage ran for free",
            t.name
        );
    }
}

#[test]
fn tenancy_is_deterministic() {
    let plan = "big:@0 accel=4 csd=2 batches=80 prio=hi; a:@2 accel=2 csd=1 batches=30; \
                b:@4 accel=2 csd=1 batches=30 prio=lo";
    for sched in Sched::ALL {
        let c = cfg(4, 2, plan, sched);
        let r1 = run_toy(&c);
        let r2 = run_toy(&c);
        assert_eq!(r1.fleet, r2.fleet, "sched={sched}");
        assert_eq!(r1.trace.spans, r2.trace.spans, "sched={sched}");
        for (a, b) in r1.tenants.iter().zip(r2.tenants.iter()) {
            assert_eq!(a.start, b.start, "sched={sched}");
            assert_eq!(a.finish, b.finish, "sched={sched}");
            assert_eq!(a.accel_ids, b.accel_ids, "sched={sched}");
            assert_eq!(a.csd_ids, b.csd_ids, "sched={sched}");
            assert_eq!(a.result.report, b.result.report, "sched={sched}");
            assert_eq!(a.result.trace.spans, b.result.trace.spans, "sched={sched}");
        }
    }
}

#[test]
fn released_slice_unblocks_queued_job_mid_run() {
    // Two half-fleet jobs run side by side; a third queues until the
    // *first* of them releases — not until the whole fleet drains.
    let plan = "left:@0 accel=2 csd=1 batches=30; right:@0 accel=2 csd=1 batches=240; \
                late:@1 accel=2 csd=1 batches=30";
    let r = run_toy(&cfg(4, 2, plan, Sched::Fifo));
    let (left, right, late) = (&r.tenants[0], &r.tenants[1], &r.tenants[2]);
    assert_eq!(left.start, 0.0);
    assert_eq!(right.start, 0.0);
    assert!(left.finish < right.finish, "toy workloads out of order");
    // `late` started exactly when the short job released its slice —
    // while the long job was still running — and inherited its devices.
    assert_eq!(late.start, left.finish);
    assert!(late.start < right.finish, "late waited for the whole fleet");
    assert_eq!(late.accel_ids, left.accel_ids);
    assert_eq!(late.csd_ids, left.csd_ids);
}
