//! Shared integration-test plumbing.

use ddlp::config::ExperimentConfig;
use ddlp::coordinator::cost::CostProvider;
use ddlp::coordinator::Session;
use ddlp::dataset::DatasetSpec;
use ddlp::metrics::RunReport;
use ddlp::topology::Topology;
use ddlp::trace::Trace;

/// The old `run_schedule(cfg, spec, costs)` call shape, expressed
/// through the Session API over the topology the config describes.
pub fn run_session(
    cfg: &ExperimentConfig,
    spec: &DatasetSpec,
    costs: &mut (dyn CostProvider + Send),
) -> anyhow::Result<(RunReport, Trace)> {
    let r = Session::with_costs(cfg, Topology::from_config(cfg)?, spec, costs)?.run()?;
    Ok((r.report, r.trace))
}
