//! Multi-accelerator (§IV-E) integration: DistributedSampler sharding,
//! per-GPU CSD directories, and the Table VI 2-GPU rows' shape.

use ddlp::config::{DeviceProfile, ExperimentConfig};
use ddlp::coordinator::cost::FixedCosts;
use ddlp::coordinator::{Session, Strategy};
use ddlp::dataset::DatasetSpec;
use ddlp::pipeline::PipelineKind;
use ddlp::trace::{Device, Phase};

mod common;
use common::run_session;

/// The old `run_experiment` call shape (analytic costs from the config).
fn run_experiment(cfg: &ExperimentConfig) -> anyhow::Result<ddlp::coordinator::RunResult> {
    Session::from_config(cfg)?.run()
}

fn cfg(strategy: Strategy, n_accel: u32, n: u32, workers: u32) -> ExperimentConfig {
    let mut profile = DeviceProfile::default();
    profile.csd_signal_latency_s = 0.0;
    profile.poll_cost_s = 0.0;
    ExperimentConfig::builder()
        .model("resnet152")
        .pipeline_kind(PipelineKind::ImageNet1)
        .strategy(strategy)
        .n_accel(n_accel)
        .num_workers(workers)
        .n_batches(n)
        .profile(profile)
        .build()
        .unwrap()
}

fn spec(n: u32) -> DatasetSpec {
    DatasetSpec {
        n_batches: n,
        batch_size: 1,
        pipeline: PipelineKind::ImageNet1,
        seed: 0,
    }
}

#[test]
fn two_gpus_cover_dataset_disjointly() {
    for strategy in Strategy::ALL {
        let mut costs = FixedCosts::toy_fig6();
        let c = cfg(strategy, 2, 200, 0);
        let (report, trace) = run_session(&c, &spec(200), &mut costs).unwrap();
        assert_eq!(report.n_batches, 200, "{strategy}");
        // every batch trained exactly once, split across two devices
        let mut seen = vec![0u8; 200];
        let mut per_dev = [0u32; 2];
        for s in trace.spans.iter().filter(|s| s.phase == Phase::Train) {
            seen[s.batch.unwrap() as usize] += 1;
            match s.device {
                Device::Accel(i) => per_dev[i as usize] += 1,
                d => panic!("train on {d:?}"),
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{strategy}: coverage");
        assert_eq!(per_dev[0] + per_dev[1], 200);
        assert!(per_dev[0] > 0 && per_dev[1] > 0, "{strategy}: both GPUs used");
    }
}

#[test]
fn two_gpus_improve_throughput() {
    // Table VI rows 6–7: 2-GPU per-batch learning time beats 1-GPU.
    for strategy in [Strategy::CpuOnly, Strategy::Mte, Strategy::Wrr] {
        let one = run_experiment(&cfg(strategy, 1, 400, 16)).unwrap().report;
        let two = run_experiment(&cfg(strategy, 2, 400, 16)).unwrap().report;
        assert!(
            two.learn_time_per_batch < one.learn_time_per_batch,
            "{strategy}: 2-GPU {:.3} !< 1-GPU {:.3}",
            two.learn_time_per_batch,
            one.learn_time_per_batch
        );
    }
}

#[test]
fn two_gpu_ddlp_beats_two_gpu_cpu_baseline() {
    let cpu = run_experiment(&cfg(Strategy::CpuOnly, 2, 400, 0)).unwrap().report;
    let mte = run_experiment(&cfg(Strategy::Mte, 2, 400, 0)).unwrap().report;
    let wrr = run_experiment(&cfg(Strategy::Wrr, 2, 400, 0)).unwrap().report;
    assert!(mte.learn_time_per_batch < cpu.learn_time_per_batch);
    assert!(wrr.learn_time_per_batch <= mte.learn_time_per_batch * 1.01);
}

#[test]
fn csd_directories_keyed_by_gpu() {
    // WRR round-robins CSD products across per-GPU directories: both
    // accelerators must consume CSD-sourced batches.
    let mut costs = FixedCosts::toy_fig6();
    let c = cfg(Strategy::Wrr, 2, 400, 0);
    let (_, trace) = run_session(&c, &spec(400), &mut costs).unwrap();
    let mut gds_per_dev = [0u32; 2];
    for s in trace.spans.iter().filter(|s| s.phase == Phase::GdsRead) {
        if let Device::Accel(i) = s.device {
            gds_per_dev[i as usize] += 1;
        }
    }
    assert!(
        gds_per_dev[0] > 0 && gds_per_dev[1] > 0,
        "csd batches per gpu: {gds_per_dev:?}"
    );
    // round-robin keeps the split balanced within a generous factor
    let (a, b) = (gds_per_dev[0] as f64, gds_per_dev[1] as f64);
    assert!(a / b < 2.0 && b / a < 2.0, "unbalanced: {gds_per_dev:?}");
}

#[test]
fn worker_budget_validated_and_clamped() {
    // The host-wide worker budget is split across per-accelerator
    // DataLoaders. A non-zero budget below n_accel used to truncate to
    // 0 workers per host silently; the builder now rejects it.
    let err = ExperimentConfig::builder()
        .model("resnet152")
        .num_workers(2)
        .n_accel(4)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("num_workers"), "{err}");

    // A hand-built config that bypasses the builder is clamped to at
    // least one worker per host instead of degrading to main-process
    // loading.
    let mut c = cfg(Strategy::Wrr, 2, 100, 2);
    c.num_workers = 1; // budget 1 across 2 accelerators
    let mut costs = FixedCosts::toy_fig6();
    let (report, trace) = run_session(&c, &spec(100), &mut costs).unwrap();
    assert_eq!(report.n_batches, 100);
    let worker_busy = trace.busy_where(|s| matches!(s.device, Device::CpuWorker(_)));
    assert!(worker_busy > 0.0, "clamp failed: no worker lanes used");
    let mut seen = vec![0u8; 100];
    for s in trace.spans.iter().filter(|s| s.phase == Phase::Train) {
        seen[s.batch.unwrap() as usize] += 1;
    }
    assert!(seen.iter().all(|&n| n == 1), "coverage broken under clamp");
}

#[test]
fn four_gpus_still_consistent() {
    let mut costs = FixedCosts::toy_fig6();
    let c = cfg(Strategy::Wrr, 4, 403, 0); // non-divisible shard sizes
    let (report, trace) = run_session(&c, &spec(403), &mut costs).unwrap();
    assert_eq!(report.n_batches, 403);
    let mut seen = vec![0u8; 403];
    for s in trace.spans.iter().filter(|s| s.phase == Phase::Train) {
        seen[s.batch.unwrap() as usize] += 1;
    }
    assert!(seen.iter().all(|&c| c == 1));
}
