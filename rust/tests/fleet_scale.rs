//! Fleet-scale scheduling: the O(log n) selection structure must be
//! observationally identical to the old per-iteration linear scans, and
//! the full scheduler must keep its exactly-once invariants at
//! accelerator counts far beyond the paper's testbed (ISSUE 3 /
//! DESIGN.md §Performance weak-scaling model).

use ddlp::config::{DeviceProfile, ExperimentConfig};
use ddlp::coordinator::cost::FixedCosts;
use ddlp::coordinator::Strategy;
use ddlp::dataset::DatasetSpec;
use ddlp::pipeline::PipelineKind;
use ddlp::trace::{Phase, Trace};
use ddlp::util::idxheap::IdxMinHeap;
use ddlp::util::prop::run_prop;

mod common;
use common::run_session;

/// The engine's pre-heap selection rule, verbatim: linear scan over the
/// member set, `Iterator::min_by` on `total_cmp` keys (first minimal
/// element wins on exact ties).
fn linear_min(keys: &[f64], member: &[bool]) -> Option<usize> {
    (0..keys.len())
        .filter(|&a| member[a])
        .min_by(|&x, &y| keys[x].total_cmp(&keys[y]))
}

/// Bit-exact heap/scan agreement on random monotone `free_at`
/// sequences — the engine's actual update pattern: keys only ever grow
/// (lane clocks are monotone), members leave when their shard
/// finishes. Keys are drawn from a coarse grid so **exact f64 ties**
/// are common, and zero-sized bumps re-key members with equal keys.
#[test]
fn prop_idxheap_pop_order_matches_linear_scan() {
    run_prop("idxheap == min_by scan on monotone free_at", 200, |g| {
        let n = g.size(1, 64);
        let mut heap = IdxMinHeap::new(n);
        let mut keys = vec![0.0f64; n];
        let mut member = vec![true; n];
        for a in 0..n {
            // Mixed starting clocks, grid-aligned for ties.
            keys[a] = g.int(0, 6) as f64 * 0.5;
            heap.upsert(a, keys[a]);
        }
        for _ in 0..g.size(0, 200) {
            let selected = heap.peek();
            assert_eq!(selected, linear_min(&keys, &member));
            let Some(a) = selected else { break };
            // Advance the selected accelerator's clock like `consume`
            // does (possibly by exactly 0 — a pure re-key on a tie), or
            // finish it like shard exhaustion does.
            if g.int(0, 5) == 0 {
                member[a] = false;
                heap.remove(a);
            } else {
                keys[a] += g.int(0, 4) as f64 * 0.5;
                heap.upsert(a, keys[a]);
            }
            // Occasionally revive a departed slot (epoch-boundary
            // re-insertion) — upsert-on-absent churn.
            if g.int(0, 7) == 0 {
                let b = g.size(0, n - 1);
                if !member[b] {
                    keys[b] += g.int(0, 4) as f64 * 0.5;
                    member[b] = true;
                    heap.upsert(b, keys[b]);
                }
            }
        }
        // Drain what is left: pop order must equal repeated scans.
        while let Some(a) = heap.peek() {
            assert_eq!(Some(a), linear_min(&keys, &member));
            member[a] = false;
            heap.remove(a);
        }
        assert_eq!(linear_min(&keys, &member), None);
    });
}

fn spec(n: u32) -> DatasetSpec {
    DatasetSpec {
        n_batches: n,
        batch_size: 1,
        pipeline: PipelineKind::ImageNet1,
        seed: 0,
    }
}

/// Every batch id 0..n is trained exactly once per epoch.
fn assert_exact_coverage(trace: &Trace, n: u32, epochs: u32, label: &str) {
    let mut counts = vec![0u32; n as usize];
    for s in &trace.spans {
        if s.phase == Phase::Train {
            counts[s.batch.unwrap() as usize] += 1;
        }
    }
    for (b, &c) in counts.iter().enumerate() {
        assert_eq!(c, epochs, "{label}: batch {b} trained {c}×, want {epochs}");
    }
}

/// Large-fleet smoke: all five strategies at n_accel = 64 (16× the
/// paper's testbed) keep every-batch-exactly-once across epochs, with
/// and without DataLoader workers.
#[test]
fn fleet64_every_strategy_exactly_once() {
    const N_ACCEL: u32 = 64;
    const N_BATCHES: u32 = N_ACCEL * 10;
    const EPOCHS: u32 = 2;
    let mut profile = DeviceProfile::default();
    profile.csd_signal_latency_s = 0.0;
    for strategy in Strategy::ALL {
        for workers in [0u32, N_ACCEL] {
            let label = format!("{strategy} workers={workers}");
            let c = ExperimentConfig::builder()
                .model("wrn")
                .pipeline_kind(PipelineKind::ImageNet1)
                .strategy(strategy)
                .num_workers(workers)
                .n_accel(N_ACCEL)
                .n_batches(N_BATCHES)
                .epochs(EPOCHS)
                .profile(profile.clone())
                .build()
                .unwrap();
            let mut costs = FixedCosts::toy_fig6();
            let (report, trace) = run_session(&c, &spec(N_BATCHES), &mut costs).unwrap();
            assert_eq!(report.n_batches, N_BATCHES * EPOCHS, "{label}");
            assert_exact_coverage(&trace, N_BATCHES, EPOCHS, &label);
        }
    }
}

/// Large-fleet cluster smoke: 64 accelerators partitioned over 4 hosts
/// (16 CSDs, epoch stealing armed) keep exactly-once coverage across
/// epochs — the fleet-scale invariants survive the multi-host split.
#[test]
fn fleet64_cluster_exactly_once_with_stealing() {
    use ddlp::cluster::{Cluster, StealMode};
    use ddlp::coordinator::cost::CostProvider;

    const N_ACCEL: u32 = 64;
    const N_BATCHES: u32 = N_ACCEL * 8;
    const EPOCHS: u32 = 2;
    let mut profile = DeviceProfile::default();
    profile.csd_signal_latency_s = 0.0;
    for strategy in [Strategy::Wrr, Strategy::Mte] {
        let label = format!("cluster {strategy}");
        let c = ExperimentConfig::builder()
            .model("wrn")
            .pipeline_kind(PipelineKind::ImageNet1)
            .strategy(strategy)
            .n_hosts(4)
            .n_accel(N_ACCEL)
            .n_csd(16)
            .steal(StealMode::Epoch)
            .n_batches(N_BATCHES)
            .epochs(EPOCHS)
            .profile(profile.clone())
            .build()
            .unwrap();
        let r = Cluster::from_config(&c)
            .unwrap()
            .with_cost_factory(|h| -> Box<dyn CostProvider + Send> {
                // Host 0 drags: stealing must fire and stay exact.
                let mut costs = FixedCosts::toy_fig6();
                if h == 0 {
                    costs.host.pp_s *= 2.0;
                    costs.csd.pp_s *= 2.0;
                }
                Box::new(costs)
            })
            .run()
            .unwrap();
        assert_eq!(r.report.n_batches, N_BATCHES * EPOCHS, "{label}");
        assert_exact_coverage(&r.trace, N_BATCHES, EPOCHS, &label);
        assert_eq!(r.host_reports.len(), 4, "{label}");
        let host_sum: u64 = r.host_reports.iter().map(|h| h.batches()).sum();
        assert_eq!(host_sum, (N_BATCHES * EPOCHS) as u64, "{label}");
    }
}

/// Ragged fleet: n_batches not divisible by n_accel (some shards one
/// batch longer), plus an n_accel > n_batches config where trailing
/// shards are empty — the first-unfinished cursor and the heap must
/// both cope with never-members.
#[test]
fn fleet_ragged_and_empty_shards() {
    let mut profile = DeviceProfile::default();
    profile.csd_signal_latency_s = 0.0;
    for (n_accel, n_batches) in [(48u32, 500u32), (64, 40)] {
        for strategy in Strategy::ALL {
            let label = format!("{strategy} n_accel={n_accel} n={n_batches}");
            let c = ExperimentConfig::builder()
                .model("wrn")
                .pipeline_kind(PipelineKind::ImageNet1)
                .strategy(strategy)
                .num_workers(0)
                .n_accel(n_accel)
                .n_batches(n_batches)
                .profile(profile.clone())
                .build()
                .unwrap();
            let mut costs = FixedCosts::toy_fig6();
            let (report, trace) = run_session(&c, &spec(n_batches), &mut costs).unwrap();
            assert_eq!(report.n_batches, n_batches, "{label}");
            assert_exact_coverage(&trace, n_batches, 1, &label);
        }
    }
}
