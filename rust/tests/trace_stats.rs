//! Streaming trace aggregation (`TraceStats`):
//!
//! 1. Property: for random span sets, every stats accumulator equals
//!    the corresponding `busy_where` filter-and-sum **bit-exactly**
//!    (insertion-order accumulation), and the stats survive
//!    `stats_only` mode unchanged.
//! 2. End-to-end: `RunReport`s are bit-identical between stats-only
//!    (`record_trace(false)`) and full-trace runs for every strategy ×
//!    accelerator count — the old `record_trace(false)` zeroed-fields
//!    gap stays closed.

use ddlp::config::ExperimentConfig;
use ddlp::coordinator::cost::FixedCosts;
use ddlp::coordinator::Strategy;
use ddlp::dataset::DatasetSpec;
use ddlp::pipeline::PipelineKind;
use ddlp::trace::{Device, DeviceClass, Phase, Span, Trace};
use ddlp::util::prop::run_prop;

mod common;
use common::run_session;

const DEVICES: [Device; 7] = [
    Device::CpuMain,
    Device::CpuWorker(0),
    Device::CpuWorker(1),
    Device::CpuWorker(2),
    Device::Csd,
    Device::Accel(0),
    Device::Accel(1),
];

#[test]
fn prop_stats_equal_busy_where_bitwise() {
    run_prop("TraceStats == busy_where (bit-exact)", 100, |g| {
        let mut full = Trace::new();
        let mut lean = Trace::stats_only();
        let n = g.size(0, 60);
        for _ in 0..n {
            let dev = *g.choose(&DEVICES);
            let phase = *g.choose(&Phase::ALL);
            let start = g.float(0.0, 50.0);
            let dur = g.float(0.0, 5.0);
            let batch = if g.bool() { Some(g.int(0, 1000) as u32) } else { None };
            full.record(dev, phase, batch, start, start + dur);
            lean.record(dev, phase, batch, start, start + dur);
        }
        let st = full.stats();

        // Per-class × per-phase cells match the filtered span sums.
        for class in DeviceClass::ALL {
            for phase in Phase::ALL {
                let expect = full
                    .busy_where(|s: &Span| s.device.class() == class && s.phase == phase);
                assert_eq!(
                    st.busy(class, phase).to_bits(),
                    expect.to_bits(),
                    "cell ({class:?}, {phase:?})"
                );
            }
        }
        // Dedicated report accumulators match their predicates.
        assert_eq!(
            st.t_io().to_bits(),
            full.busy_where(|s| s.phase == Phase::SsdRead).to_bits()
        );
        assert_eq!(
            st.t_cpu().to_bits(),
            full.busy_where(|s| s.phase == Phase::CpuPreprocess).to_bits()
        );
        assert_eq!(
            st.t_csd().to_bits(),
            full.busy_where(|s| s.device == Device::Csd).to_bits()
        );
        assert_eq!(
            st.t_gpu().to_bits(),
            full.busy_where(|s| s.phase == Phase::Train).to_bits()
        );
        assert_eq!(
            st.t_gds().to_bits(),
            full.busy_where(|s| s.phase == Phase::GdsRead).to_bits()
        );
        assert_eq!(
            st.host_busy().to_bits(),
            full.busy_where(|s| s.device.is_host_cpu()).to_bits()
        );
        // Makespan matches the old full-scan fold.
        let scan = full.spans.iter().map(|s| s.end).fold(0.0f64, f64::max);
        assert_eq!(st.makespan().to_bits(), scan.to_bits());
        assert_eq!(st.n_spans(), full.spans.len() as u64);

        // stats_only mode: no spans stored, identical statistics.
        assert!(lean.spans.is_empty());
        assert_eq!(lean.stats(), st);
    });
}

fn report_pair(
    strategy: Strategy,
    n_accel: u32,
    workers: u32,
    record_trace: bool,
) -> ddlp::metrics::RunReport {
    let n_batches = 96;
    let cfg = ExperimentConfig::builder()
        .model("wrn")
        .pipeline_kind(PipelineKind::ImageNet1)
        .strategy(strategy)
        .num_workers(workers)
        .n_accel(n_accel)
        .n_batches(n_batches)
        .epochs(2)
        .record_trace(record_trace)
        .build()
        .unwrap();
    let spec = DatasetSpec {
        n_batches,
        batch_size: 1,
        pipeline: PipelineKind::ImageNet1,
        seed: 0,
    };
    let mut costs = FixedCosts::toy_fig6();
    let (report, trace) = run_session(&cfg, &spec, &mut costs).unwrap();
    assert_eq!(
        trace.is_enabled(),
        record_trace,
        "trace mode must follow cfg.record_trace"
    );
    if !record_trace {
        assert!(trace.spans.is_empty(), "stats-only run must store no spans");
        assert!(trace.stats().n_spans() > 0, "stats must still accumulate");
    }
    report
}

/// `RunReport` derives `PartialEq` bit-exactly on its f64 fields, so
/// one `assert_eq!` per combination is the full field-for-field check.
#[test]
fn stats_only_reports_bit_identical_to_full_trace() {
    for strategy in Strategy::ALL {
        for n_accel in [1u32, 2, 4] {
            for workers in [0u32, 8] {
                let full = report_pair(strategy, n_accel, workers, true);
                let lean = report_pair(strategy, n_accel, workers, false);
                assert_eq!(
                    full, lean,
                    "report diverged: {strategy} n_accel={n_accel} workers={workers}"
                );
                // The old gap: these fields came back zero without spans.
                if strategy != Strategy::CsdOnly {
                    assert!(lean.t_cpu > 0.0, "{strategy}: t_cpu should be real");
                }
                if strategy.uses_csd() {
                    assert!(lean.t_csd > 0.0, "{strategy}: t_csd should be real");
                }
                assert!(lean.t_gpu > 0.0, "{strategy}: t_gpu should be real");
            }
        }
    }
}
